"""NequIP: equivariant interatomic potentials [Batzner et al.,
arXiv:2101.03164], built on ``repro.models.gnn.irreps``.

Interaction block (per layer):

    msg_ij = sum_paths  W_path(rbf(r_ij))[c] * CG_(l1,l2->l3)
                        ( h_j[c, l1] (x) Y_l2(r^_ij) )
    h_i'   = SelfInteract_l( h_i + (1/sqrt(deg_avg)) sum_j msg_ij )
    h_i''  = Gate(h_i')           # scalars: silu; l>0: sigmoid-scalar gate

Assigned config: n_layers=5, d_hidden=32 (uniform multiplicity per l),
l_max=2, n_rbf=8 (Bessel basis), cutoff=5 A.  The tensor product is
channel-wise ("depthwise", as in NequIP) with per-path radial weights.

Rotation equivariance is exact (property-tested); O(3) parity
bookkeeping is folded (see irreps.py note).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense_init
from repro.models.gnn import irreps as IR
from repro.models.gnn.graph import GraphBatch, agg_sum, graph_readout


@dataclasses.dataclass(frozen=True)
class NequIPConfig:
    name: str = "nequip"
    n_layers: int = 5
    d_hidden: int = 32          # channel multiplicity per degree
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    d_in: int = 16              # species embedding input dim
    n_out: int = 1
    radial_hidden: int = 64
    avg_degree: float = 10.0
    dtype: Any = jnp.float32

    @property
    def comps(self) -> int:
        return IR.num_comps(self.l_max)

    @property
    def paths(self):
        return IR.allowed_paths(self.l_max, self.l_max, self.l_max)


# -------------------------------------------------------------------------
# Radial basis
# -------------------------------------------------------------------------
def bessel_rbf(r, n_rbf: int, cutoff: float, eps: float = 1e-9):
    """Bessel basis sqrt(2/c) sin(k pi r / c) / r with polynomial cutoff."""
    k = jnp.arange(1, n_rbf + 1, dtype=r.dtype)
    rr = jnp.maximum(r, eps)[..., None]
    basis = math.sqrt(2.0 / cutoff) * jnp.sin(k * jnp.pi * rr / cutoff) / rr
    # smooth polynomial envelope (p = 6)
    x = jnp.clip(r / cutoff, 0.0, 1.0)
    env = 1 - 28 * x**6 + 48 * x**7 - 21 * x**8
    return basis * env[..., None]


def _mlp_init(key, dims, dtype):
    ks = jax.random.split(key, len(dims) - 1)
    return [{"w": dense_init(k, a, b, dtype), "b": jnp.zeros((b,), dtype)}
            for k, a, b in zip(ks, dims[:-1], dims[1:])]


def _mlp(params, x, act=jax.nn.silu):
    for i, lay in enumerate(params):
        x = x @ lay["w"] + lay["b"]
        if i < len(params) - 1:
            x = act(x)
    return x


# -------------------------------------------------------------------------
# Params
# -------------------------------------------------------------------------
def init_params(cfg: NequIPConfig, key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    c = cfg.d_hidden
    n_paths = len(cfg.paths)
    ks = jax.random.split(key, cfg.n_layers + 2)
    layers = []
    for i in range(cfg.n_layers):
        k_r, k_s, k_g = jax.random.split(ks[i], 3)
        layers.append({
            # radial MLP: rbf -> per-(path, channel) TP weights
            "radial": _mlp_init(k_r, [cfg.n_rbf, cfg.radial_hidden,
                                      n_paths * c], cfg.dtype),
            # self-interaction: per-degree channel mixing
            "self": [dense_init(jax.random.fold_in(k_s, l), c, c, cfg.dtype)
                     / np.sqrt(c) * np.sqrt(c)  # keep unit scale
                     for l in range(cfg.l_max + 1)],
            # gate scalars for l > 0
            "gate": dense_init(k_g, c, c * cfg.l_max, cfg.dtype),
        })
    return {
        "embed": _mlp_init(ks[-2], [cfg.d_in, c], cfg.dtype),
        "layers": layers,
        "head": _mlp_init(ks[-1], [c, c, cfg.n_out], cfg.dtype),
    }


def param_specs(cfg: NequIPConfig):
    p = init_params(dataclasses.replace(cfg, n_layers=1, d_hidden=4,
                                        d_in=2, radial_hidden=4))
    return jax.tree.map(lambda _: (), p)


# -------------------------------------------------------------------------
# Forward
# -------------------------------------------------------------------------
def _tensor_product(cfg: NequIPConfig, h_src, Y, w):
    """Depthwise TP: h_src [E, C, K], Y [E, K], w [E, n_paths, C] ->
    messages [E, C, K]."""
    e = h_src.shape[0]
    out = jnp.zeros((e, cfg.d_hidden, cfg.comps), h_src.dtype)
    for p, (l1, l2, l3) in enumerate(cfg.paths):
        cg = jnp.asarray(IR.cg_real(l1, l2, l3), h_src.dtype)
        lhs = h_src[..., IR.l_slice(l1)]               # [E, C, 2l1+1]
        rhs = Y[..., IR.l_slice(l2)]                   # [E, 2l2+1]
        m = jnp.einsum("ijk,eci,ej->eck", cg, lhs, rhs)
        out = out.at[..., IR.l_slice(l3)].add(m * w[:, p, :, None])
    return out


def _layer(lp, h, batch: GraphBatch, Y, rbf, cfg: NequIPConfig):
    s, r = batch.senders, batch.receivers
    n1 = batch.n_node + 1
    c = cfg.d_hidden
    w = _mlp(lp["radial"], rbf).reshape(-1, len(cfg.paths), c)
    w = w * batch.edge_mask[:, None, None].astype(w.dtype)
    msgs = _tensor_product(cfg, h[s], Y, w)
    agg = agg_sum(msgs, r, n1) / np.sqrt(cfg.avg_degree)
    h = h + agg
    # self interaction per degree
    outs = []
    for l in range(cfg.l_max + 1):
        blk = h[..., IR.l_slice(l)]
        outs.append(jnp.einsum("cd,ncm->ndm", lp["self"][l], blk))
    h = jnp.concatenate(outs, axis=-1)
    # gate nonlinearity
    scal = h[..., 0]                                   # [N+1, C]
    gates = jax.nn.sigmoid(scal @ lp["gate"]).reshape(-1, cfg.l_max, c)
    new = [jax.nn.silu(scal)[..., None]]
    for l in range(1, cfg.l_max + 1):
        g = jnp.swapaxes(gates[:, l - 1, :], -1, -1)[..., None]  # [N+1, C, 1]
        new.append(h[..., IR.l_slice(l)] * g)
    return jnp.concatenate(new, axis=-1)


def forward(params, batch: GraphBatch, cfg: NequIPConfig):
    """Returns (graph energies [G, n_out], node irreps [N+1, C, K])."""
    s, r = batch.senders, batch.receivers
    rel = batch.pos[r] - batch.pos[s]
    dist = jnp.linalg.norm(rel, axis=-1)
    Y = IR.sph_harm(cfg.l_max, rel).astype(cfg.dtype)
    rbf = bessel_rbf(dist, cfg.n_rbf, cfg.cutoff).astype(cfg.dtype)

    h0 = _mlp(params["embed"], batch.nodes.astype(cfg.dtype))   # [N+1, C]
    h = jnp.zeros((batch.n_node + 1, cfg.d_hidden, cfg.comps), cfg.dtype)
    h = h.at[..., 0].set(h0)
    for lp in params["layers"]:
        h = _layer(lp, h, batch, Y, rbf, cfg)
    node_e = _mlp(params["head"], h[..., 0])
    node_e = node_e * batch.node_mask[:, None].astype(node_e.dtype)
    g = graph_readout(node_e, batch.graph_id, batch.n_graph, "sum")
    return g, h


def node_forward(params, batch: GraphBatch, cfg: NequIPConfig):
    """Node-level outputs [n_node, n_out] (classification shapes)."""
    _, h = forward(params, batch, cfg)
    return _mlp(params["head"], h[..., 0])[: batch.n_node]


def make_loss(cfg: NequIPConfig):
    def loss_fn(params, batch_and_target):
        batch, target = batch_and_target
        g, _ = forward(params, batch, cfg)
        return jnp.mean((g - target) ** 2)
    return loss_fn
