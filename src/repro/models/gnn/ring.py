"""Ring-partitioned equivariant graph attention (SPerf cell-B).

Problem: full-batch Equiformer-v2 on ogb_products keeps node irreps
[2.45M, 128, 49] REPLICATED per device under the baseline edge-sharded
plan -- 61 GiB/device, 5.6 TiB total temp (measured; EXPERIMENTS.md
SPerf).  The node state must be sharded, and then message passing needs
remote sender rows.

Scheme (2D ring, exact):
* nodes are partitioned into ``p_data`` blocks; node state lives
  sharded P("data") and REPLICATED over "model";
* edges are bucketed host-side by (dst block d, model column m, step s)
  where ``s = (d - src_block) mod p_data``; each (d, m) device holds
  ``p_data`` fixed-capacity buckets;
* step ``s`` fetches the sender block at ring distance ``s`` with ONE
  ``ppermute`` (shift-by-s, not a chained rotation: each step is then
  independently rematerializable, which keeps the backward pass O(1) in
  saved state);
* attention softmax over incoming edges is computed in TWO phases so no
  big accumulator is chained through the step loop (only the [n_loc, H]
  running (max, denom) stats are):
    phase 1: streaming log-sum-exp of the alpha logits per dst node;
    phase 2: out = sum_s segment_sum(msg_s * exp(alpha_s - m) / l) --
    independent terms, each inside jax.checkpoint;
* partial (m, l, out) combine across the "model" axis with pmax/psum
  (the flash-attention merge, across chips).

Numerically exact vs the local path (property-tested in
tests/launch/test_ring_subprocess.py on a 2x2 host mesh).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.gnn import equiformer_v2 as E2
from repro.models.gnn import irreps as IR


# -------------------------------------------------------------------------
# Host-side bucketing
# -------------------------------------------------------------------------
def bucket_edges(senders, receivers, n_nodes: int, p_data: int,
                 p_model: int, cap: int | None = None):
    """Bucket edges by (dst block, model column, ring step).

    Returns (src_loc, dst_loc) int32[p_data, p_model, p_data, cap] with
    pad sentinel = n_loc (the dump row of each block), plus n_loc.
    Model columns are filled round-robin per (d, s) for load balance.
    """
    n_loc = -(-n_nodes // p_data)
    n_pad = n_loc * p_data
    senders = np.asarray(senders)
    receivers = np.asarray(receivers)
    d_blk = receivers // n_loc
    s_blk = senders // n_loc
    step = (d_blk - s_blk) % p_data
    # per (d, m, s) bucket fill
    buckets_src = [[[[] for _ in range(p_data)] for _ in range(p_model)]
                   for _ in range(p_data)]
    buckets_dst = [[[[] for _ in range(p_data)] for _ in range(p_model)]
                   for _ in range(p_data)]
    rr = {}
    for e in range(len(senders)):
        d, s = int(d_blk[e]), int(step[e])
        m = rr.get((d, s), 0)
        rr[(d, s)] = (m + 1) % p_model
        buckets_src[d][m][s].append(int(senders[e] % n_loc))
        buckets_dst[d][m][s].append(int(receivers[e] % n_loc))
    if cap is None:
        cap = max(1, max((len(b) for row in buckets_src for col in row
                          for b in col), default=1))
    src = np.full((p_data, p_model, p_data, cap), n_loc, np.int32)
    dst = np.full((p_data, p_model, p_data, cap), n_loc, np.int32)
    dropped = 0
    for d in range(p_data):
        for m in range(p_model):
            for s in range(p_data):
                bs = buckets_src[d][m][s][:cap]
                bd = buckets_dst[d][m][s][:cap]
                dropped += max(len(buckets_src[d][m][s]) - cap, 0)
                src[d, m, s, :len(bs)] = bs
                dst[d, m, s, :len(bd)] = bd
    return src, dst, n_loc, dropped


def bucket_specs(n_nodes: int, n_edges: int, p_data: int, p_model: int,
                 slack: float = 4.0):
    """ShapeDtypeStruct buckets for the dry-run (capacity via slack)."""
    n_loc = -(-n_nodes // p_data)
    cap = int(np.ceil(n_edges * slack / (p_data * p_model * p_data)))
    cap = max(-(-cap // 8) * 8, 8)
    sds = jax.ShapeDtypeStruct
    shape = (p_data, p_model, p_data, cap)
    return sds(shape, jnp.int32), sds(shape, jnp.int32), n_loc


# -------------------------------------------------------------------------
# Device code
# -------------------------------------------------------------------------
def _shift_perm(p_data: int, s: int):
    """ppermute perm fetching the block at ring distance s."""
    return [(i, (i + s) % p_data) for i in range(p_data)]


def _ring_attn_local(lp, x_loc, pos_loc, src_b, dst_b, cfg, p_data: int,
                     data_axis: str, model_axis: str):
    """Per-device body (inside shard_map).

    x_loc [n_loc(+1), C, K] (last row = dump), pos_loc [n_loc(+1), 3],
    src_b/dst_b local view [1, 1, p_data, cap] -> squeezed here.
    """
    src_b = src_b[0, 0]
    dst_b = dst_b[0, 0]
    n1 = x_loc.shape[0]                      # n_loc + 1 (dump row)
    heads = cfg.n_heads

    # phase 1: streaming max of alpha, entirely under stop_gradient (the
    # log-sum-exp max shift is analytically gradient-free).  Static
    # python loop: ppermute permutations must be concrete.
    m = jnp.full((n1, heads), -1e30, jnp.float32)
    xs = jax.lax.stop_gradient(x_loc)
    ps = jax.lax.stop_gradient(pos_loc)
    lps = jax.lax.stop_gradient(lp)
    for s in range(p_data):
        def body(x_in, p_in, s=s):
            x_blk = jax.lax.ppermute(x_in, data_axis, _shift_perm(p_data, s))
            p_blk = jax.lax.ppermute(p_in, data_axis, _shift_perm(p_data, s))
            src, dst = src_b[s], dst_b[s]
            rel = p_in[dst] - p_blk[src]
            _, alpha = E2.edge_messages(lps, x_blk[src], x_in[dst], rel, cfg)
            alpha = jnp.where((src < n1 - 1)[:, None], alpha, -1e30)
            return jax.ops.segment_max(alpha, dst, num_segments=n1)

        # no jax.checkpoint here: everything is stop-gradded constant, so
        # nothing is saved for bwd (and checkpoint would materialize
        # zero tangents into pmax, which has no JVP rule)
        blk_max = body(xs, ps)
        m = jnp.maximum(m, jnp.nan_to_num(blk_max, neginf=-1e30))
        # serialize the steps: without a data dependence the scheduler
        # keeps all 16 steps' message buffers live at once (measured
        # 3.4 TiB/device; EXPERIMENTS.md cell-B it-2)
        m, xs, ps = jax.lax.optimization_barrier((m, xs, ps))
    m = jax.lax.stop_gradient(jax.lax.pmax(m, model_axis))

    # phase 2: independent (numerator, denominator) contributions; both
    # differentiable, divided only at the end (exact softmax gradients).
    num = jnp.zeros((n1, cfg.d_hidden, cfg.comps), jnp.float32)
    den = jnp.zeros((n1, heads), jnp.float32)
    for s in range(p_data):
        def body2(x_in, p_in, m, s=s):
            x_blk = jax.lax.ppermute(x_in, data_axis, _shift_perm(p_data, s))
            p_blk = jax.lax.ppermute(p_in, data_axis, _shift_perm(p_data, s))
            src, dst = src_b[s], dst_b[s]
            rel = p_in[dst] - p_blk[src]
            msg, alpha = E2.edge_messages(lp, x_blk[src], x_in[dst], rel,
                                          cfg)
            live = (src < n1 - 1)[:, None]
            # mask BEFORE exp: exp(garbage - (-1e30)) = inf would poison
            # the where-gradient (inf * 0 = NaN in the cotangent)
            shifted = jnp.where(live, alpha - m[dst], -1e30)
            w = jnp.exp(shifted)
            msg = E2.head_weight(w, msg.astype(jnp.float32), cfg)
            return (jax.ops.segment_sum(msg, dst, num_segments=n1),
                    jax.ops.segment_sum(w, dst, num_segments=n1))

        dn, dd = jax.checkpoint(body2)(x_loc, pos_loc, m)
        num = num + dn
        den = den + dd
        num, den, x_loc, pos_loc = jax.lax.optimization_barrier(
            (num, den, x_loc, pos_loc))
    num = jax.lax.psum(num, model_axis)
    den = jnp.maximum(jax.lax.psum(den, model_axis), 1e-30)
    hsz = cfg.d_hidden // cfg.n_heads
    out = num / jnp.repeat(den, hsz, axis=-1)[..., None]
    return out.astype(x_loc.dtype)


def make_ring_attn(mesh: Mesh, cfg, p_data: int,
                   data_axis: str = "data", model_axis: str = "model"):
    """shard_map-wrapped ring attention:
    (layer_params, x [p_data*(n_loc+1), C, K] sharded data,
     pos likewise, buckets sharded (data, model)) -> aggregated messages
    (same sharding as x)."""

    local = functools.partial(_ring_attn_local, cfg=cfg, p_data=p_data,
                              data_axis=data_axis, model_axis=model_axis)

    return jax.shard_map(
        local,
        mesh=mesh,
        in_specs=(P(), P(data_axis), P(data_axis),
                  P(data_axis, model_axis), P(data_axis, model_axis)),
        out_specs=P(data_axis),
    )


# -------------------------------------------------------------------------
# Full ring forward (node-sharded everything outside attention)
# -------------------------------------------------------------------------
def forward_ring(params, nodes, pos, src_b, dst_b, cfg, mesh,
                 p_data: int):
    """nodes [p_data*(n_loc+1), F], pos likewise (each block carries its
    own dump row so block-local indices hit block-local pads).

    Returns node irreps (sharded like the inputs).
    """
    ring_attn = make_ring_attn(mesh, cfg, p_data)
    h0 = E2._lin(params["embed"], nodes.astype(cfg.dtype))
    x = jnp.zeros(nodes.shape[:1] + (cfg.d_hidden, cfg.comps), cfg.dtype)
    x = x.at[..., 0].set(h0)
    for lp in params["layers"]:
        h = IR.equivariant_rms_norm(cfg.l_max, x, lp["norm1"])
        agg = ring_attn(lp, h, pos, src_b, dst_b)
        x = x + E2.out_project(lp, agg, cfg)
        h = IR.equivariant_rms_norm(cfg.l_max, x, lp["norm2"])
        x = x + E2._ffn(lp, h, cfg)
    return x


def blocked_layout(node_feat, pos, n_nodes: int, p_data: int):
    """Host-side: rearrange [N, F] into p_data blocks each with a dump
    row appended -> [p_data * (n_loc + 1), F]."""
    n_loc = -(-n_nodes // p_data)
    f = node_feat.shape[1]
    out = np.zeros((p_data * (n_loc + 1), f), node_feat.dtype)
    pout = np.zeros((p_data * (n_loc + 1), 3), pos.dtype)
    for b in range(p_data):
        lo, hi = b * n_loc, min((b + 1) * n_loc, n_nodes)
        out[b * (n_loc + 1): b * (n_loc + 1) + (hi - lo)] = node_feat[lo:hi]
        pout[b * (n_loc + 1): b * (n_loc + 1) + (hi - lo)] = pos[lo:hi]
    return out, pout, n_loc
