"""Equiformer-v2: equivariant graph attention via eSCN convolutions
[Liao et al., arXiv:2306.12059; Passaro & Zitnick, arXiv:2302.03655].

Core idea (eSCN): rotate each edge's features into a frame where the
edge direction is the SH polar axis; in that frame an equivariant convolution
with SH filters reduces to an *SO(2) linear* that only mixes components
of equal |m| -- and truncating to |m| <= m_max (here 2) cuts the O(L^6)
tensor product to O(L^3) work with negligible accuracy loss.

Layer = equivariant-norm -> eSCN multi-head attention -> residual ->
equivariant-norm -> gated FFN -> residual.

Assigned config: n_layers=12, d_hidden=128, l_max=6, m_max=2, n_heads=8.

TPU adaptation notes:
* per-edge Wigner matrices are built by the CG recurrence
  (``irreps.wigner_d``) -- dense [2l+1, 2l+1] blocks, batched over edges
  (MXU-friendly), instead of the host-precomputed caches of the CUDA
  implementation;
* the m-truncated representation is laid out as three dense tensors
  (m = 0 real, m = 1, 2 complex pairs) so every SO(2) linear is one
  matmul over a [E, *] operand.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense_init
from repro.models.gnn import irreps as IR
from repro.models.gnn.graph import GraphBatch, agg_sum, graph_readout


@dataclasses.dataclass(frozen=True)
class EquiformerV2Config:
    name: str = "equiformer-v2"
    n_layers: int = 12
    d_hidden: int = 128          # sphere channels
    l_max: int = 6
    m_max: int = 2
    n_heads: int = 8
    d_in: int = 16
    n_out: int = 1
    n_rbf: int = 64              # gaussian distance basis
    cutoff: float = 5.0
    ffn_mult: int = 2
    dtype: Any = jnp.float32

    @property
    def comps(self) -> int:
        return IR.num_comps(self.l_max)

    def n_l(self, m: int) -> int:
        """Number of degrees carrying an |m| component."""
        return self.l_max + 1 - m


def gaussian_rbf(r, n_rbf: int, cutoff: float):
    centers = jnp.linspace(0.0, cutoff, n_rbf).astype(r.dtype)
    width = cutoff / n_rbf
    return jnp.exp(-((r[..., None] - centers) / width) ** 2)


# -------------------------------------------------------------------------
# m-truncated representation <-> full irreps
# -------------------------------------------------------------------------
def _m_indices(cfg: EquiformerV2Config, m: int):
    """Flat component indices of (+m, -m) per degree l >= m."""
    plus = [l * l + l + m for l in range(m, cfg.l_max + 1)]
    minus = [l * l + l - m for l in range(m, cfg.l_max + 1)]
    return np.asarray(plus), np.asarray(minus)


def to_m_rep(cfg: EquiformerV2Config, x):
    """x [..., C, K] -> (m0 [..., C, L+1], [(xp, xm) per m=1..m_max])."""
    p0, _ = _m_indices(cfg, 0)
    m0 = x[..., p0]
    pairs = []
    for m in range(1, cfg.m_max + 1):
        pl, mi = _m_indices(cfg, m)
        pairs.append((x[..., pl], x[..., mi]))
    return m0, pairs


def from_m_rep(cfg: EquiformerV2Config, m0, pairs, like):
    """Inverse of ``to_m_rep``; components with |m| > m_max are zero."""
    out = jnp.zeros(like.shape[:-1] + (cfg.comps,), m0.dtype)
    p0, _ = _m_indices(cfg, 0)
    out = out.at[..., p0].set(m0)
    for m, (xp, xm) in enumerate(pairs, start=1):
        pl, mi = _m_indices(cfg, m)
        out = out.at[..., pl].set(xp)
        out = out.at[..., mi].set(xm)
    return out


# -------------------------------------------------------------------------
# Params
# -------------------------------------------------------------------------
def _lin_init(key, a, b, dtype):
    return {"w": dense_init(key, a, b, dtype), "b": jnp.zeros((b,), dtype)}


def _lin(p, x):
    return x @ p["w"] + p["b"]


def _so2_init(key, cfg: EquiformerV2Config, c_in_mult: int, dtype):
    """SO(2) linear weights: m=0 real matrix + complex (Wr, Wi) per m>0."""
    c = cfg.d_hidden
    ks = jax.random.split(key, 2 * cfg.m_max + 1)
    p = {"m0": _lin_init(ks[0], c_in_mult * c * (cfg.l_max + 1) + cfg.n_rbf,
                         c * (cfg.l_max + 1), dtype)}
    for m in range(1, cfg.m_max + 1):
        din = c_in_mult * c * cfg.n_l(m)
        dout = c * cfg.n_l(m)
        p[f"m{m}r"] = dense_init(ks[2 * m - 1], din, dout, dtype)
        p[f"m{m}i"] = dense_init(ks[2 * m], din, dout, dtype)
    return p


def _so2_apply(p, cfg: EquiformerV2Config, m0_in, pairs_in, rbf):
    """Apply the SO(2) linear.  m0_in [E, *], pairs [E, *]; returns
    (m0 [E, C, L+1], pairs [(E, C, n_l) x2])."""
    e = m0_in.shape[0]
    c = cfg.d_hidden
    m0_flat = jnp.concatenate(
        [m0_in.reshape(e, -1), rbf.astype(m0_in.dtype)], axis=-1)
    m0 = _lin(p["m0"], m0_flat).reshape(e, c, cfg.l_max + 1)
    pairs = []
    for m, (xp, xm) in enumerate(pairs_in, start=1):
        zp, zm = xp.reshape(e, -1), xm.reshape(e, -1)
        wr, wi = p[f"m{m}r"], p[f"m{m}i"]
        op = (zp @ wr - zm @ wi).reshape(e, c, cfg.n_l(m))
        om = (zm @ wr + zp @ wi).reshape(e, c, cfg.n_l(m))
        pairs.append((op, om))
    return m0, pairs


def init_params(cfg: EquiformerV2Config, key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    c = cfg.d_hidden
    ks = jax.random.split(key, cfg.n_layers + 2)
    layers = []
    for i in range(cfg.n_layers):
        ka, kv, kal, ko, kf1, kf2, kg, kn = jax.random.split(ks[i], 8)
        layers.append({
            "norm1": jnp.ones((c, cfg.l_max + 1), cfg.dtype),
            "so2": _so2_init(ka, cfg, 2, cfg.dtype),      # src+dst features
            "alpha": _lin_init(kal, c * (cfg.l_max + 1), cfg.n_heads,
                               cfg.dtype),
            "out": [dense_init(jax.random.fold_in(ko, l), c, c, cfg.dtype)
                    for l in range(cfg.l_max + 1)],
            "norm2": jnp.ones((c, cfg.l_max + 1), cfg.dtype),
            "ffn_in": _lin_init(kf1, c, cfg.ffn_mult * c, cfg.dtype),
            "ffn_out": _lin_init(kf2, cfg.ffn_mult * c, c, cfg.dtype),
            "ffn_gate": dense_init(kg, c, c * cfg.l_max, cfg.dtype),
            "ffn_self": [dense_init(jax.random.fold_in(kn, l), c, c,
                                    cfg.dtype)
                         for l in range(cfg.l_max + 1)],
        })
    return {
        "embed": _lin_init(ks[-2], cfg.d_in, c, cfg.dtype),
        "layers": layers,
        "head": _lin_init(ks[-1], c, cfg.n_out, cfg.dtype),
    }


def param_specs(cfg: EquiformerV2Config):
    p = init_params(dataclasses.replace(
        cfg, n_layers=1, d_hidden=8, d_in=2, l_max=2, m_max=1, n_heads=2,
        n_rbf=4))
    return jax.tree.map(lambda _: (), p)


# -------------------------------------------------------------------------
# Attention block
# -------------------------------------------------------------------------
def _segment_softmax(logits, seg, n_rows, mask):
    """logits [E, H] -> softmax over edges per segment (receiver)."""
    logits = jnp.where(mask[:, None], logits, -jnp.inf)
    mx = jax.ops.segment_max(logits, seg, num_segments=n_rows)
    mx = jnp.nan_to_num(mx, neginf=0.0)
    ex = jnp.where(mask[:, None], jnp.exp(logits - mx[seg]), 0.0)
    den = jax.ops.segment_sum(ex, seg, num_segments=n_rows)
    return ex / (den[seg] + 1e-9)


def edge_messages(lp, x_src, x_dst, rel, cfg: EquiformerV2Config):
    """Shared eSCN message core: (x_src, x_dst) [E, C, K] + rel [E, 3]
    -> (msg [E, C, K] rotated back to the global frame, alpha logits
    [E, H]).  Used by the local path and the ring path (SPerf cell-B)."""
    dist = jnp.sqrt(jnp.sum(rel * rel, axis=-1) + 1e-18)  # grad-safe at 0
    rbf = gaussian_rbf(dist, cfg.n_rbf, cfg.cutoff)
    Ds = IR.wigner_d(cfg.l_max, IR.rot_to_polar(rel))
    xs = IR.apply_wigner(cfg.l_max, Ds, x_src)
    xd = IR.apply_wigner(cfg.l_max, Ds, x_dst)
    m0s, ps = to_m_rep(cfg, xs)
    m0d, pd = to_m_rep(cfg, xd)
    m0_in = jnp.concatenate([m0s, m0d], axis=-2)          # [E, 2C, L+1]
    pairs_in = [(jnp.concatenate([a, c2], -2), jnp.concatenate([b, d2], -2))
                for (a, b), (c2, d2) in zip(ps, pd)]
    m0, pairs = _so2_apply(lp["so2"], cfg, m0_in, pairs_in, rbf)
    m0 = jax.nn.silu(m0)
    alpha = jax.nn.leaky_relu(
        _lin(lp["alpha"], m0.reshape(m0.shape[0], -1)), 0.2)  # [E, H]
    msg = from_m_rep(cfg, m0, pairs, xs)
    DsT = [jnp.swapaxes(D, -1, -2) for D in Ds]
    return IR.apply_wigner(cfg.l_max, DsT, msg), alpha


def head_weight(alpha_w, msg, cfg: EquiformerV2Config):
    """Scale value channels by per-head attention weights [E, H]."""
    hsz = cfg.d_hidden // cfg.n_heads
    return msg * jnp.repeat(alpha_w, hsz, axis=-1)[..., None]


def out_project(lp, agg, cfg: EquiformerV2Config):
    outs = [jnp.einsum("cd,ncm->ndm", lp["out"][l], agg[..., IR.l_slice(l)])
            for l in range(cfg.l_max + 1)]
    return jnp.concatenate(outs, axis=-1)


def _attn_block(lp, x, batch: GraphBatch, Ds, rbf, cfg: EquiformerV2Config):
    s, r = batch.senders, batch.receivers
    n1 = batch.n_node + 1
    rel = (batch.pos[r] - batch.pos[s]).astype(x.dtype)
    msg, alpha = edge_messages(lp, x[s], x[r], rel, cfg)
    alpha = _segment_softmax(alpha, r, n1, batch.edge_mask)   # [E, H]
    msg = head_weight(alpha, msg, cfg)
    msg = msg * batch.edge_mask[:, None, None].astype(msg.dtype)
    agg = agg_sum(msg, r, n1)
    return out_project(lp, agg, cfg)


def _ffn(lp, x, cfg: EquiformerV2Config):
    scal = x[..., 0]
    hid = jax.nn.silu(_lin(lp["ffn_in"], scal))
    scal_out = _lin(lp["ffn_out"], hid)
    gates = jax.nn.sigmoid(scal @ lp["ffn_gate"]).reshape(
        scal.shape[:-1] + (cfg.l_max, cfg.d_hidden))
    outs = [scal_out[..., None]]
    for l in range(1, cfg.l_max + 1):
        blk = jnp.einsum("cd,ncm->ndm", lp["ffn_self"][l],
                         x[..., IR.l_slice(l)])
        outs.append(blk * jnp.swapaxes(gates[..., l - 1, :], -1, -1)[..., None])
    return jnp.concatenate(outs, axis=-1)


def _layer(lp, x, batch, cfg):
    h = IR.equivariant_rms_norm(cfg.l_max, x, lp["norm1"])
    x = x + _attn_block(lp, h, batch, None, None, cfg)
    h = IR.equivariant_rms_norm(cfg.l_max, x, lp["norm2"])
    x = x + _ffn(lp, h, cfg)
    return x


def forward(params, batch: GraphBatch, cfg: EquiformerV2Config):
    """Returns (graph outputs [G, n_out], node irreps [N+1, C, K]).

    Per-edge Wigner blocks are recomputed inside each layer (CG
    recurrence) instead of held across layers -- trades ~5% FLOPs for
    not pinning the [E, sum(2l+1)^2] buffers, and matches the ring path.
    """
    h0 = _lin(params["embed"], batch.nodes.astype(cfg.dtype))
    x = jnp.zeros((batch.n_node + 1, cfg.d_hidden, cfg.comps), cfg.dtype)
    x = x.at[..., 0].set(h0)
    for lp in params["layers"]:
        x = _layer(lp, x, batch, cfg)
    node_out = _lin(params["head"], x[..., 0])
    node_out = node_out * batch.node_mask[:, None].astype(node_out.dtype)
    g = graph_readout(node_out, batch.graph_id, batch.n_graph, "sum")
    return g, x


def node_forward(params, batch: GraphBatch, cfg: EquiformerV2Config):
    """Node-level outputs [n_node, n_out] (classification shapes)."""
    _, x = forward(params, batch, cfg)
    return _lin(params["head"], x[..., 0])[: batch.n_node]


def make_loss(cfg: EquiformerV2Config):
    def loss_fn(params, batch_and_target):
        batch, target = batch_and_target
        g, _ = forward(params, batch, cfg)
        return jnp.mean((g - target) ** 2)
    return loss_fn
