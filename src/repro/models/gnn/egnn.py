"""EGNN: E(n)-equivariant graph network [Satorras et al., arXiv:2102.09844].

The cheap equivariant model: messages depend on invariants (h_i, h_j,
||x_i - x_j||^2), coordinates update along relative vectors:

    m_ij  = phi_e(h_i, h_j, ||x_i - x_j||^2)
    x_i' = x_i + C * sum_j (x_i - x_j) * phi_x(m_ij)
    h_i' = phi_h(h_i, sum_j m_ij)

Assigned config: n_layers=4, d_hidden=64.  Equivariance: outputs
(energies) are E(n)-invariant, coordinates are E(n)-equivariant
(tested in tests/models/test_gnn.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import dense_init
from repro.models.gnn.graph import GraphBatch, agg_sum, graph_readout


@dataclasses.dataclass(frozen=True)
class EGNNConfig:
    name: str = "egnn"
    n_layers: int = 4
    d_hidden: int = 64
    d_in: int = 16
    n_out: int = 1                   # graph-level targets (energy)
    coord_agg_mean: bool = True      # C = 1/deg (stabilizes large graphs)
    dtype: Any = jnp.float32


def _mlp_init(key, dims, dtype):
    ks = jax.random.split(key, len(dims) - 1)
    return [{"w": dense_init(k, a, b, dtype), "b": jnp.zeros((b,), dtype)}
            for k, a, b in zip(ks, dims[:-1], dims[1:])]


def _mlp(params, x, act=jax.nn.silu, last_act=False):
    for i, lay in enumerate(params):
        x = x @ lay["w"] + lay["b"]
        if i < len(params) - 1 or last_act:
            x = act(x)
    return x


def _mlp_spec(params):
    return [{"w": (None, None), "b": (None,)} for _ in params]


def init_params(cfg: EGNNConfig, key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    ks = jax.random.split(key, cfg.n_layers + 2)
    h = cfg.d_hidden
    layers = []
    for i in range(cfg.n_layers):
        k_e, k_x, k_h = jax.random.split(ks[i], 3)
        layers.append({
            "phi_e": _mlp_init(k_e, [2 * h + 1, h, h], cfg.dtype),
            "phi_x": _mlp_init(k_x, [h, h, 1], cfg.dtype),
            "phi_h": _mlp_init(k_h, [2 * h, h, h], cfg.dtype),
        })
    return {
        "embed": _mlp_init(ks[-2], [cfg.d_in, h], cfg.dtype),
        "layers": layers,
        "head": _mlp_init(ks[-1], [h, h, cfg.n_out], cfg.dtype),
    }


def param_specs(cfg: EGNNConfig):
    p = init_params(dataclasses.replace(cfg, d_hidden=4, d_in=2, n_layers=1),
                    jax.random.PRNGKey(0))
    spec = jax.tree.map(lambda _: None, p)
    # replicate everything (GNN weights are tiny); edges carry the sharding
    return jax.tree.map(lambda _: (), spec, is_leaf=lambda x: x is None)


def _layer(lp, h, x, batch: GraphBatch, cfg: EGNNConfig):
    s, r = batch.senders, batch.receivers
    n1 = batch.n_node + 1
    rel = x[r] - x[s]                                     # x_i - x_j at recv i
    d2 = jnp.sum(rel * rel, axis=-1, keepdims=True)
    m = _mlp(lp["phi_e"], jnp.concatenate([h[r], h[s], d2], -1),
             last_act=True)                               # [E, h]
    m = m * batch.edge_mask[:, None].astype(m.dtype)
    # coordinate update
    w = _mlp(lp["phi_x"], m)                              # [E, 1]
    coord_msg = rel * w
    dx = agg_sum(coord_msg, r, n1)
    if cfg.coord_agg_mean:
        deg = agg_sum(batch.edge_mask.astype(x.dtype), r, n1)
        dx = dx / (deg[:, None] + 1.0)
    x = x + dx
    # feature update
    magg = agg_sum(m, r, n1)
    h = h + _mlp(lp["phi_h"], jnp.concatenate([h, magg], -1))
    return h, x


def forward(params, batch: GraphBatch, cfg: EGNNConfig):
    """Returns (graph_out [G, n_out], h [N+1, d], x [N+1, 3])."""
    h = _mlp(params["embed"], batch.nodes.astype(cfg.dtype))
    x = batch.pos.astype(cfg.dtype)
    for lp in params["layers"]:
        h, x = _layer(lp, h, x, batch, cfg)
    node_out = _mlp(params["head"], h)
    node_out = node_out * batch.node_mask[:, None].astype(node_out.dtype)
    g = graph_readout(node_out, batch.graph_id, cfg_n_graph(batch), "sum")
    return g, h, x


def cfg_n_graph(batch: GraphBatch) -> int:
    return batch.n_graph


def node_forward(params, batch: GraphBatch, cfg: EGNNConfig):
    """Node-level logits [n_node, n_out] (for classification shapes)."""
    h = _mlp(params["embed"], batch.nodes.astype(cfg.dtype))
    x = batch.pos.astype(cfg.dtype)
    for lp in params["layers"]:
        h, x = _layer(lp, h, x, batch, cfg)
    return _mlp(params["head"], h)[: batch.n_node]


def make_loss(cfg: EGNNConfig):
    def loss_fn(params, batch_and_target):
        batch, target = batch_and_target
        g, _, _ = forward(params, batch, cfg)
        return jnp.mean((g - target) ** 2)
    return loss_fn
