"""K-hop neighbor sampler (GraphSAGE-style) for the ``minibatch_lg`` shape.

Host-side (numpy) over a CSR adjacency; emits fixed-shape padded
``GraphBatch`` blocks so the device step is recompile-free:

* layer capacities are ``batch_nodes * prod(fanout[:i])``;
* sampled subgraphs smaller than capacity are dump-padded;
* features are gathered host-side (the real-cluster analogue is a
  sharded feature server; here the synthetic features live in host RAM).

The sampler is deterministic given (seed, step) -- required for
checkpoint-restart reproducibility (see repro.train.loop).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.models.gnn.graph import GraphBatch, from_numpy


@dataclasses.dataclass
class CSRGraph:
    """Host-side CSR adjacency."""
    indptr: np.ndarray   # int64[n + 1]
    indices: np.ndarray  # int32[m]
    feat: np.ndarray     # float32[n, d]
    labels: np.ndarray   # int32[n]

    @property
    def n(self) -> int:
        return len(self.indptr) - 1


def synthetic_csr(n: int, avg_deg: int, d_feat: int, n_classes: int = 41,
                  seed: int = 0) -> CSRGraph:
    """Power-law-ish synthetic graph in CSR (host RAM only)."""
    rng = np.random.default_rng(seed)
    # preferential-attachment-flavoured degree skew
    deg = np.minimum(
        rng.zipf(1.7, size=n).astype(np.int64), 50 * avg_deg)
    deg = np.maximum((deg * avg_deg / max(deg.mean(), 1)).astype(np.int64), 1)
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(deg, out=indptr[1:])
    m = int(indptr[-1])
    indices = rng.integers(0, n, size=m).astype(np.int32)
    feat = rng.normal(size=(n, d_feat)).astype(np.float32)
    labels = rng.integers(0, n_classes, size=n).astype(np.int32)
    return CSRGraph(indptr=indptr, indices=indices, feat=feat, labels=labels)


def sample_block_caps(batch_nodes: int, fanout: Sequence[int]):
    """(node_cap, edge_cap) of the padded sampled subgraph."""
    node_cap = batch_nodes
    edge_cap = 0
    layer = batch_nodes
    for f in fanout:
        edge_cap += layer * f
        layer *= f
        node_cap += layer
    return node_cap, edge_cap


class NeighborSampler:
    """Uniform k-hop fanout sampler producing padded GraphBatch blocks."""

    def __init__(self, g: CSRGraph, batch_nodes: int, fanout: Sequence[int],
                 seed: int = 0):
        self.g = g
        self.batch_nodes = batch_nodes
        self.fanout = tuple(fanout)
        self.seed = seed
        self.node_cap, self.edge_cap = sample_block_caps(batch_nodes, fanout)

    def sample(self, step: int):
        """Returns (GraphBatch, target_labels int32[batch_nodes],
        target_slots int32[batch_nodes])."""
        rng = np.random.default_rng((self.seed, step))
        g = self.g
        targets = rng.integers(0, g.n, size=self.batch_nodes).astype(np.int64)

        # node dedup table: global id -> local slot
        local = {}
        order = []

        def slot(v: int) -> int:
            s = local.get(v)
            if s is None:
                s = len(order)
                local[v] = s
                order.append(v)
            return s

        for v in targets:
            slot(int(v))
        senders, receivers = [], []
        frontier = [int(v) for v in targets]
        for f in self.fanout:
            nxt = []
            for v in frontier:
                lo, hi = g.indptr[v], g.indptr[v + 1]
                if hi == lo:
                    continue
                nbrs = g.indices[lo + rng.integers(0, hi - lo, size=f)]
                for u in nbrs:
                    u = int(u)
                    senders.append(slot(u))
                    receivers.append(local[v])
                    nxt.append(u)
            frontier = nxt
        n_used = len(order)
        ids = np.asarray(order, dtype=np.int64)
        feat = np.zeros((self.node_cap, g.feat.shape[1]), np.float32)
        feat[:n_used] = g.feat[ids]
        # pad node table to capacity; dump-row handled by from_numpy
        batch = from_numpy(
            feat,
            np.asarray(senders, np.int32),
            np.asarray(receivers, np.int32),
            e_cap=self.edge_cap,
        )
        labels = g.labels[targets].astype(np.int32)
        slots = np.arange(self.batch_nodes, dtype=np.int32)  # targets first
        return batch, labels, slots
