"""Fixed-shape padded graph batches (the GNN substrate's data format).

Same conventions as the DSPC core graph (``repro.core.graph``): one extra
"dump" node row absorbs padded edges, so every array is static-shape and
jit/pjit-friendly.

* node arrays have ``n_node + 1`` rows; row ``n_node`` is the dump row.
* padded edge slots point at ``(n_node, n_node)``.
* ``graph_id`` supports batched small graphs (the ``molecule`` shape):
  node -> graph assignment, dump row -> ``n_graph`` (a dump graph).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GraphBatch:
    nodes: jax.Array            # f[N + 1, F] node features (dump row zeros)
    senders: jax.Array          # int32[E] (pad = N)
    receivers: jax.Array        # int32[E] (pad = N)
    pos: Optional[jax.Array]    # f[N + 1, 3] positions or None
    graph_id: jax.Array         # int32[N + 1] (dump row = G)
    n_node: int = dataclasses.field(metadata=dict(static=True))
    n_graph: int = dataclasses.field(metadata=dict(static=True))

    @property
    def n_edge(self) -> int:
        return self.senders.shape[0]

    @property
    def node_mask(self) -> jax.Array:
        return jnp.arange(self.n_node + 1) < self.n_node

    @property
    def edge_mask(self) -> jax.Array:
        return self.senders != self.n_node


def batch_spec(n_node: int, n_edge: int, d_feat: int, *, with_pos: bool,
               n_graph: int = 1, dtype=jnp.float32) -> GraphBatch:
    """ShapeDtypeStruct stand-in batch (for dry-runs / eval_shape)."""
    sds = jax.ShapeDtypeStruct
    return GraphBatch(
        nodes=sds((n_node + 1, d_feat), dtype),
        senders=sds((n_edge,), jnp.int32),
        receivers=sds((n_edge,), jnp.int32),
        pos=sds((n_node + 1, 3), dtype) if with_pos else None,
        graph_id=sds((n_node + 1,), jnp.int32),
        n_node=n_node,
        n_graph=n_graph,
    )


def from_numpy(node_feat: np.ndarray, senders: np.ndarray,
               receivers: np.ndarray, *, pos: np.ndarray | None = None,
               graph_id: np.ndarray | None = None, n_graph: int = 1,
               e_cap: int | None = None) -> GraphBatch:
    """Host-side constructor with dump-row padding."""
    n, f = node_feat.shape
    e = len(senders)
    e_cap = e_cap or e
    assert e <= e_cap
    nodes = np.zeros((n + 1, f), node_feat.dtype)
    nodes[:n] = node_feat
    s = np.full(e_cap, n, dtype=np.int32)
    r = np.full(e_cap, n, dtype=np.int32)
    s[:e] = senders
    r[:e] = receivers
    gid = np.full(n + 1, n_graph, dtype=np.int32)
    gid[:n] = graph_id if graph_id is not None else 0
    p = None
    if pos is not None:
        p = np.zeros((n + 1, 3), pos.dtype)
        p[:n] = pos
    return GraphBatch(
        nodes=jnp.asarray(nodes), senders=jnp.asarray(s),
        receivers=jnp.asarray(r),
        pos=jnp.asarray(p) if p is not None else None,
        graph_id=jnp.asarray(gid), n_node=n, n_graph=n_graph)


# -------------------------------------------------------------------------
# Segment aggregations over edges -> nodes (the message-passing primitive).
# All take per-edge values [E, ...] and receivers [E]; the dump row makes
# padded edges harmless.
# -------------------------------------------------------------------------
def agg_sum(msgs, receivers, n_rows):
    return jax.ops.segment_sum(msgs, receivers, num_segments=n_rows)


def agg_mean(msgs, receivers, n_rows, eps=1e-9):
    tot = agg_sum(msgs, receivers, n_rows)
    deg = jax.ops.segment_sum(jnp.ones_like(receivers, msgs.dtype),
                              receivers, num_segments=n_rows)
    return tot / (deg[:, None] + eps), deg


def agg_max(msgs, receivers, n_rows):
    return jax.ops.segment_max(msgs, receivers, num_segments=n_rows)


def agg_min(msgs, receivers, n_rows):
    return jax.ops.segment_min(msgs, receivers, num_segments=n_rows)


def agg_std(msgs, receivers, n_rows, eps=1e-9):
    mean, deg = agg_mean(msgs, receivers, n_rows, eps)
    sq, _ = agg_mean(msgs * msgs, receivers, n_rows, eps)
    var = jnp.maximum(sq - mean * mean, 0.0)
    return jnp.sqrt(var + eps), mean, deg


def degrees(receivers, n_rows, dtype=jnp.float32):
    return jax.ops.segment_sum(
        jnp.ones_like(receivers, dtype), receivers, num_segments=n_rows)


def graph_readout(node_vals, graph_id, n_graph, op: str = "sum"):
    """Per-graph readout (molecule batches); drops the dump graph."""
    if op == "sum":
        out = jax.ops.segment_sum(node_vals, graph_id, num_segments=n_graph + 1)
    elif op == "mean":
        tot = jax.ops.segment_sum(node_vals, graph_id, num_segments=n_graph + 1)
        cnt = jax.ops.segment_sum(jnp.ones_like(graph_id, node_vals.dtype),
                                  graph_id, num_segments=n_graph + 1)
        out = tot / jnp.maximum(cnt[:, None], 1.0)
    else:
        raise ValueError(op)
    return out[:n_graph]
