"""DIEN: Deep Interest Evolution Network [Zhou et al., arXiv:1809.03672].

CTR model over user behavior sequences:

  1. **Embedding layer** -- item + category id embeddings (the huge
     sparse tables; row-sharded on the model axis) plus multi-hot user
     profile fields reduced with the EmbeddingBag primitive
     (``jnp.take`` + ``segment_sum``; Pallas kernel on TPU).
  2. **Interest extractor** -- GRU over the behavior sequence, with the
     auxiliary loss (next-behavior discrimination vs sampled negatives).
  3. **Interest evolution** -- attention scores between the target item
     and extractor states drive an **AUGRU** (GRU whose update gate is
     scaled by the attention weight).
  4. **MLP head** -- mlp=200-80 -> logit (PReLU activations).

Assigned config: embed_dim=18, seq_len=100, gru_dim=108, mlp=200-80,
interaction=augru.

Shapes: ``train_batch`` (65536) lowers the train step; ``serve_p99`` /
``serve_bulk`` lower the scoring forward; ``retrieval_cand`` scores one
user state against 10^6 candidates as a single batched dot against the
(sharded) item table -- the industry two-tower retrieval pattern, NOT a
per-candidate AUGRU loop (the evolution path is target-conditioned and
is reserved for ranking).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense_init


@dataclasses.dataclass(frozen=True)
class DIENConfig:
    name: str = "dien"
    embed_dim: int = 18
    seq_len: int = 100
    gru_dim: int = 108
    mlp: tuple = (200, 80)
    n_items: int = 4_000_000
    n_cates: int = 10_000
    n_profile_vocab: int = 100_000   # hashed multi-hot profile features
    profile_bags: int = 4            # multi-hot fields (EmbeddingBag)
    bag_size: int = 8                # nnz per bag (padded)
    aux_weight: float = 1.0
    dtype: Any = jnp.float32
    unroll_scans: bool = False   # roofline-measurement mode (see
                                 # transformer.TransformerConfig)

    @property
    def beh_dim(self) -> int:        # item + cate embedding concat
        return 2 * self.embed_dim


# -------------------------------------------------------------------------
# Params
# -------------------------------------------------------------------------
def _gru_init(key, d_in, d_h, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wx": dense_init(k1, d_in, 3 * d_h, dtype),   # update/reset/cand
        "wh": dense_init(k2, d_h, 3 * d_h, dtype),
        "b": jnp.zeros((3 * d_h,), dtype),
    }


def _mlp_init(key, dims, dtype):
    ks = jax.random.split(key, len(dims) - 1)
    layers = []
    for k, a, b in zip(ks, dims[:-1], dims[1:]):
        layers.append({"w": dense_init(k, a, b, dtype),
                       "b": jnp.zeros((b,), dtype),
                       "p": jnp.full((b,), 0.25, dtype)})  # PReLU slope
    return layers


def init_params(cfg: DIENConfig, key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)
    d, h = cfg.beh_dim, cfg.gru_dim
    head_in = h + cfg.beh_dim + cfg.profile_bags * cfg.embed_dim
    return {
        "item_table": dense_init(ks[0], cfg.n_items, cfg.embed_dim,
                                 cfg.dtype, scale=0.01),
        "cate_table": dense_init(ks[1], cfg.n_cates, cfg.embed_dim,
                                 cfg.dtype, scale=0.01),
        "profile_table": dense_init(ks[2], cfg.n_profile_vocab,
                                    cfg.embed_dim, cfg.dtype, scale=0.01),
        "gru": _gru_init(ks[3], d, h, cfg.dtype),
        "augru": _gru_init(ks[4], d, h, cfg.dtype),
        "attn": dense_init(ks[5], h, cfg.beh_dim, cfg.dtype),
        "head": _mlp_init(ks[6], (head_in,) + tuple(cfg.mlp) + (1,),
                          cfg.dtype),
        "aux": _mlp_init(ks[7], (h + d, 100, 1), cfg.dtype),
    }


def param_specs(cfg: DIENConfig):
    return {
        "item_table": ("table_rows", None),
        "cate_table": ("table_rows", None),
        "profile_table": ("table_rows", None),
        "gru": {"wx": (), "wh": (), "b": ()},
        "augru": {"wx": (), "wh": (), "b": ()},
        "attn": (),
        "head": [{"w": (), "b": (), "p": ()} for _ in
                 range(len(cfg.mlp) + 1)],
        "aux": [{"w": (), "b": (), "p": ()} for _ in range(2)],
    }


def _prelu_mlp(layers, x, last_linear=True):
    for i, lay in enumerate(layers):
        x = x @ lay["w"] + lay["b"]
        if i < len(layers) - 1 or not last_linear:
            x = jnp.where(x >= 0, x, lay["p"] * x)
    return x


# -------------------------------------------------------------------------
# Embedding ops (the recsys hot path)
# -------------------------------------------------------------------------
def behavior_embed(params, item_ids, cate_ids):
    """[B, T] ids -> [B, T, 2 * embed_dim]."""
    it = jnp.take(params["item_table"], item_ids, axis=0)
    ct = jnp.take(params["cate_table"], cate_ids, axis=0)
    return jnp.concatenate([it, ct], axis=-1)


def profile_embed(params, bag_ids, cfg: DIENConfig):
    """EmbeddingBag over multi-hot profile fields.

    bag_ids int32[B, bags, bag_size] (pad = n_profile_vocab - 1 with zero
    weight convention: pads point at a dedicated zero row).
    Implemented as gather + mean-reduce; on TPU the Pallas
    ``embedding_bag`` kernel implements the same contract.
    """
    b = bag_ids.shape[0]
    emb = jnp.take(params["profile_table"], bag_ids, axis=0)
    return jnp.mean(emb, axis=2).reshape(b, -1)   # [B, bags * embed_dim]


# -------------------------------------------------------------------------
# GRU / AUGRU (lax.scan over the behavior sequence)
# -------------------------------------------------------------------------
def _gru_cell(p, h, x, a=None):
    """Standard GRU; if ``a`` is given the update gate is scaled by it
    (AUGRU, [arXiv:1809.03672] eq. 7-8)."""
    gates = x @ p["wx"] + h @ p["wh"] + p["b"]
    dh = h.shape[-1]
    u = jax.nn.sigmoid(gates[..., :dh])
    r = jax.nn.sigmoid(gates[..., dh:2 * dh])
    cand_in = x @ p["wx"][:, 2 * dh:] + (r * h) @ p["wh"][:, 2 * dh:] \
        + p["b"][2 * dh:]
    c = jnp.tanh(cand_in)
    if a is not None:
        u = a * u
    return (1.0 - u) * h + u * c


def run_gru(p, xs, mask, unroll: int = 1):
    """xs [B, T, D], mask [B, T] -> all hidden states [B, T, H]."""
    b, t, _ = xs.shape
    dh = p["wh"].shape[0]
    h0 = jnp.zeros((b, dh), xs.dtype)

    def step(h, inp):
        x, m = inp
        h_new = _gru_cell(p, h, x)
        h = jnp.where(m[:, None], h_new, h)
        return h, h

    _, hs = jax.lax.scan(step, h0, (jnp.swapaxes(xs, 0, 1),
                                    jnp.swapaxes(mask, 0, 1)),
                         unroll=unroll)
    return jnp.swapaxes(hs, 0, 1)


def run_augru(p, xs, att, mask, unroll: int = 1):
    """AUGRU: att [B, T] attention scores scale the update gate."""
    b, t, _ = xs.shape
    dh = p["wh"].shape[0]
    h0 = jnp.zeros((b, dh), xs.dtype)

    def step(h, inp):
        x, a, m = inp
        h_new = _gru_cell(p, h, x, a[:, None])
        h = jnp.where(m[:, None], h_new, h)
        return h, None

    h, _ = jax.lax.scan(step, h0, (jnp.swapaxes(xs, 0, 1),
                                   jnp.swapaxes(att, 0, 1),
                                   jnp.swapaxes(mask, 0, 1)),
                        unroll=unroll)
    return h


# -------------------------------------------------------------------------
# Forward / losses
# -------------------------------------------------------------------------
def interest_states(params, batch, cfg: DIENConfig):
    """Behavior GRU states (target-independent)."""
    beh = behavior_embed(params, batch["hist_items"], batch["hist_cates"])
    unroll = cfg.seq_len if cfg.unroll_scans else 1
    return run_gru(params["gru"], beh, batch["hist_mask"],
                   unroll=unroll), beh


def forward(params, batch, cfg: DIENConfig):
    """CTR logit per example.

    batch: hist_items/hist_cates int32[B, T], hist_mask bool[B, T],
    target_item/target_cate int32[B], profile int32[B, bags, bag_size].
    """
    hs, beh = interest_states(params, batch, cfg)
    tgt = behavior_embed(params, batch["target_item"][:, None],
                         batch["target_cate"][:, None])[:, 0]   # [B, D]
    # attention: a_t = softmax(h_t W e_tgt)
    scores = jnp.einsum("bth,hd,bd->bt", hs, params["attn"], tgt)
    scores = jnp.where(batch["hist_mask"], scores, -1e30)
    att = jax.nn.softmax(scores, axis=-1)
    final = run_augru(params["augru"], beh, att, batch["hist_mask"],
                      unroll=cfg.seq_len if cfg.unroll_scans else 1)
    prof = profile_embed(params, batch["profile"], cfg)
    feats = jnp.concatenate([final, tgt, prof], axis=-1)
    return _prelu_mlp(params["head"], feats)[..., 0]            # [B]


def aux_loss(params, hs, beh, neg_beh, mask):
    """Auxiliary loss: h_t should score e_{t+1} over sampled negatives."""
    h = hs[:, :-1]                                  # [B, T-1, H]
    pos = beh[:, 1:]
    neg = neg_beh[:, 1:]
    m = mask[:, 1:].astype(h.dtype)
    pos_logit = _prelu_mlp(params["aux"],
                           jnp.concatenate([h, pos], -1))[..., 0]
    neg_logit = _prelu_mlp(params["aux"],
                           jnp.concatenate([h, neg], -1))[..., 0]
    ll = (jax.nn.log_sigmoid(pos_logit) + jax.nn.log_sigmoid(-neg_logit)) * m
    return -jnp.sum(ll) / jnp.maximum(jnp.sum(m), 1.0)


def make_train_loss(cfg: DIENConfig):
    def loss_fn(params, batch):
        hs, beh = interest_states(params, batch, cfg)
        neg_beh = behavior_embed(params, batch["neg_items"],
                                 batch["neg_cates"])
        aux = aux_loss(params, hs, beh, neg_beh, batch["hist_mask"])
        logits = forward(params, batch, cfg)
        y = batch["label"].astype(logits.dtype)
        ce = -jnp.mean(y * jax.nn.log_sigmoid(logits)
                       + (1 - y) * jax.nn.log_sigmoid(-logits))
        return ce + cfg.aux_weight * aux
    return loss_fn


def retrieval_scores(params, batch, candidate_ids, cfg: DIENConfig):
    """Score one (or few) users against n_candidates items: user vector =
    last extractor state projected through ``attn`` (target-independent),
    scores = batched dot with candidate item+cate embeddings."""
    hs, _ = interest_states(params, batch, cfg)
    lengths = jnp.sum(batch["hist_mask"].astype(jnp.int32), axis=-1)
    last = jnp.take_along_axis(
        hs, jnp.maximum(lengths - 1, 0)[:, None, None], axis=1)[:, 0]
    user_vec = last @ params["attn"]                # [B, beh_dim]
    cand = behavior_embed(params, candidate_ids["item"],
                          candidate_ids["cate"])    # [N_cand, beh_dim]
    return user_vec @ cand.T                        # [B, N_cand]
