"""Attention variants: GQA (qwen2/phi3) and MLA (deepseek-v2), with
train/prefill and decode (KV-cache) paths.

Sharding strategy (logical axes; resolved in repro.sharding):
* train/prefill: padded query heads split on "heads" -> model axis; KV
  heads replicated (GQA KV counts rarely divide TP).
* decode: the KV cache is **sequence-sharded** on the model axis
  ("cache_seq"); each shard computes partial attention and XLA combines
  the softmax reduction.  The explicit shard_map flash-decode merge (one
  log-sum-exp psum, mirroring ``repro.kernels.flash_decode`` across
  chips) is the SPerf optimization toggled by ``cfg.sharded_decode``.

Head padding: query-head counts are padded up to a multiple of the tensor
axis (zeros in the projections) so 40-head/12-head models shard on a
16-way axis -- the standard production trick (cf. vocab padding).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense_init, init_rms, rms_norm
from repro.sharding import shard_act

# attention score/prob tensors [b, h, t, s]: batch x heads sharded
SCORES = ("batch", "heads", None, None)


# -------------------------------------------------------------------------
# RoPE
# -------------------------------------------------------------------------
def rope_tables(positions, dim: int, theta: float = 10000.0):
    """positions int32[...] -> (cos, sin) [..., dim/2] fp32."""
    inv = 1.0 / (theta ** (np.arange(0, dim, 2) / dim))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x [..., dim]; rotate-half convention; cos/sin broadcast [..., dim/2]."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def pad_heads(n_heads: int, multiple: int) -> int:
    return int(-(-n_heads // multiple) * multiple)


# -------------------------------------------------------------------------
# GQA
# -------------------------------------------------------------------------
def init_gqa(key, cfg) -> tuple[dict, dict]:
    d, hq = cfg.d_model, cfg.padded_heads
    kv, dh = cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, hq * dh, cfg.param_dtype),
        "wk": dense_init(ks[1], d, kv * dh, cfg.param_dtype),
        "wv": dense_init(ks[2], d, kv * dh, cfg.param_dtype),
        "wo": dense_init(ks[3], hq * dh, d, cfg.param_dtype),
    }
    s = {
        "wq": ("embed", "heads"),
        "wk": ("embed", "kv_heads"),
        "wv": ("embed", "kv_heads"),
        "wo": ("heads", "embed"),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * dh,), cfg.param_dtype)
        p["bk"] = jnp.zeros((kv * dh,), cfg.param_dtype)
        p["bv"] = jnp.zeros((kv * dh,), cfg.param_dtype)
        s["bq"], s["bk"], s["bv"] = ("heads",), ("kv_heads",), ("kv_heads",)
    return p, s


def _proj_qkv_gqa(p, x, cfg, positions):
    b, t, d = x.shape
    hq, kv, dh = cfg.padded_heads, cfg.n_kv_heads, cfg.d_head
    q = x @ p["wq"] + (p.get("bq", 0.0))
    k = x @ p["wk"] + (p.get("bk", 0.0))
    v = x @ p["wv"] + (p.get("bv", 0.0))
    q = q.reshape(b, t, hq, dh)
    k = k.reshape(b, t, kv, dh)
    v = v.reshape(b, t, kv, dh)
    cos, sin = rope_tables(positions, dh, cfg.rope_theta)  # [b, t, dh/2]
    q = apply_rope(q, cos[:, :, None, :], sin[:, :, None, :])
    k = apply_rope(k, cos[:, :, None, :], sin[:, :, None, :])
    return q, k, v


def gqa_train(p, x, cfg, positions):
    """Causal self-attention, full sequence (train / prefill core).

    Scores are laid out [b, h, t, s] so one sharding axis covers all
    query heads (GQA KV heads are broadcast up to h; the expanded K/V
    are head-sharded so the broadcast is local and free per shard).
    """
    b, t, _ = x.shape
    hq, kv, dh = cfg.padded_heads, cfg.n_kv_heads, cfg.d_head
    q, k, v = _proj_qkv_gqa(p, x, cfg, positions)
    rep = -(-hq // kv)
    k_full = shard_act(jnp.repeat(k, rep, axis=2)[:, :, :hq],
                       ("batch", None, "heads", None))
    v_full = shard_act(jnp.repeat(v, rep, axis=2)[:, :, :hq],
                       ("batch", None, "heads", None))
    scores = jnp.einsum("bthd,bshd->bhts", q, k_full) / float(np.sqrt(dh))
    mask = jnp.tril(jnp.ones((t, t), bool))
    scores = jnp.where(mask[None, None], scores.astype(jnp.float32), -1e30)
    scores = shard_act(scores, SCORES)
    probs = shard_act(jax.nn.softmax(scores, axis=-1), SCORES).astype(x.dtype)
    ctx = jnp.einsum("bhts,bshd->bthd", probs, v_full).reshape(b, t, hq * dh)
    return ctx @ p["wo"], (k, v)


def gqa_decode(p, x, cache_k, cache_v, lengths, cfg):
    """One-token decode against a (possibly sequence-sharded) cache.

    x: [b, 1, d]; cache_k/v: [b, S, kv, dh]; lengths: int32[b] current
    valid length.  Returns (out [b, 1, d], new_k, new_v).
    """
    b = x.shape[0]
    hq, kv, dh = cfg.padded_heads, cfg.n_kv_heads, cfg.d_head
    positions = lengths[:, None]  # [b, 1]
    q, k_new, v_new = _proj_qkv_gqa(p, x, cfg, positions)
    z = jnp.int32(0)  # x64 mode: literal 0 would promote to int64
    cache_k = jax.vmap(lambda c, kn, i: jax.lax.dynamic_update_slice(
        c, kn, (i, z, z)))(cache_k, k_new, lengths)
    cache_v = jax.vmap(lambda c, vn, i: jax.lax.dynamic_update_slice(
        c, vn, (i, z, z)))(cache_v, v_new, lengths)
    s_len = cache_k.shape[1]
    # group queries by kv head; pad q up to kv * ceil(hq / kv) so head
    # counts that don't divide (phi3: 48 padded q heads, 10 kv) work.
    group = -(-hq // kv)
    hq_pad = kv * group
    q = q.reshape(b, hq, dh)
    if hq_pad != hq:
        q = jnp.pad(q, ((0, 0), (0, hq_pad - hq), (0, 0)))
    qg = q.reshape(b, kv, group, dh)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, cache_k) / float(np.sqrt(dh))
    valid = (jnp.arange(s_len)[None] <= lengths[:, None])  # includes new tok
    scores = jnp.where(valid[:, None, None], scores.astype(jnp.float32),
                       -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bkgs,bskd->bkgd", probs, cache_v)
    ctx = ctx.reshape(b, 1, hq_pad * dh)[..., :hq * dh]
    out = ctx @ p["wo"]
    return out, cache_k, cache_v


# -------------------------------------------------------------------------
# Blockwise (flash-style) attention for long prefill.
#
# Never materializes the t x t score matrix: keys/values stream in blocks
# with the online-softmax recurrence (same schedule as the flash_decode
# Pallas kernel, here across the sequence of a full prefill).  No-grad
# path: prefill is inference; training uses the plain head-sharded path.
# -------------------------------------------------------------------------
def blockwise_attention(q, make_kv_block, t_kv: int, block_k: int,
                        scale: float, q_positions, d_v: int | None = None,
                        unroll: int = 1):
    """q [b, h, t, dh]; make_kv_block(start) -> (k [b, Bk, h, dh],
    v [b, Bk, h, d_v]); causal mask via absolute positions."""
    b, h, t, dh = q.shape
    if d_v is None:
        d_v = dh
    n_blocks = -(-t_kv // block_k)
    q32 = q.astype(jnp.float32)

    def body(carry, blk):
        m, l, acc = carry
        start = blk * block_k
        k_blk, v_blk = make_kv_block(start)
        kt = k_blk.astype(jnp.float32).transpose(0, 2, 1, 3)  # [b,h,Bk,dh]
        s = jnp.einsum("bhtd,bhsd->bhts", q32, kt) * scale    # [b,h,t,Bk]
        kpos = start + jnp.arange(block_k, dtype=jnp.int32)
        mask = q_positions[:, None, :, None] >= kpos[None, None, None, :]
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhts,bshd->bhtd", p, v_blk.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, t), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, t), jnp.float32)
    acc0 = jnp.zeros((b, h, t, d_v), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0),
                                  jnp.arange(n_blocks, dtype=jnp.int32),
                                  unroll=unroll)
    return acc / jnp.maximum(l, 1e-30)[..., None]        # [b, h, t, dh]


def gqa_prefill_blockwise(p, x, cfg, positions, block_k: int = 1024):
    """GQA prefill with blockwise attention; returns (out, (k, v))."""
    b, t, _ = x.shape
    hq, kv, dh = cfg.padded_heads, cfg.n_kv_heads, cfg.d_head
    q, k, v = _proj_qkv_gqa(p, x, cfg, positions)
    q = shard_act(jnp.swapaxes(q, 1, 2), SCORES[:2] + (None, None))
    rep = -(-hq // kv)

    def kv_block(start):
        k_blk = jax.lax.dynamic_slice_in_dim(k, start, block_k, axis=1)
        v_blk = jax.lax.dynamic_slice_in_dim(v, start, block_k, axis=1)
        k_full = jnp.repeat(k_blk, rep, axis=2)[:, :, :hq]
        v_full = jnp.repeat(v_blk, rep, axis=2)[:, :, :hq]
        return k_full, v_full

    ctx = blockwise_attention(q, kv_block, t, block_k, 1.0 / float(np.sqrt(dh)),
                              positions,
                              unroll=(-(-t // block_k)
                                      if cfg.unroll_scans else 1))
    ctx = jnp.swapaxes(ctx, 1, 2).astype(x.dtype).reshape(b, t, hq * dh)
    return ctx @ p["wo"], (k, v)


def mla_prefill_blockwise(p, x, cfg, positions, block_k: int = 1024):
    """MLA prefill: k_nope/v are re-expanded from the compressed cache
    per block (never materialized at full length)."""
    b, t, _ = x.shape
    h = cfg.padded_heads
    dn, dr, dv, cl = (cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim,
                      cfg.kv_lora)
    q_nope, q_rope = _mla_q(p, x, cfg, positions)        # [b, t, h, .]
    ckv, k_rope = _mla_ckv(p, x, cfg, positions)         # [b, t, cl/dr]
    # fold the rope part into extended head dims so one blockwise pass
    # handles both terms: q_ext = [q_nope, q_rope], k_ext = [k_nope,
    # k_rope broadcast]
    q_ext = jnp.concatenate([q_nope, q_rope], axis=-1)   # [b, t, h, dn+dr]
    q_ext = shard_act(jnp.swapaxes(q_ext, 1, 2),
                      SCORES[:2] + (None, None))

    def kv_block(start):
        ckv_blk = jax.lax.dynamic_slice_in_dim(ckv, start, block_k, axis=1)
        kr_blk = jax.lax.dynamic_slice_in_dim(k_rope, start, block_k, axis=1)
        k_nope = (ckv_blk @ p["wuk"]).reshape(b, block_k, h, dn)
        kr_full = jnp.broadcast_to(kr_blk[:, :, None, :],
                                   (b, block_k, h, dr))
        k_ext = jnp.concatenate([k_nope, kr_full], axis=-1)
        v_blk = (ckv_blk @ p["wuv"]).reshape(b, block_k, h, dv)
        return k_ext, v_blk

    ctx = blockwise_attention(q_ext, kv_block, t, block_k,
                              1.0 / float(np.sqrt(dn + dr)), positions,
                              d_v=dv,
                              unroll=(-(-t // block_k)
                                      if cfg.unroll_scans else 1))
    ctx = jnp.swapaxes(ctx, 1, 2).astype(x.dtype).reshape(b, t, h * dv)
    return ctx @ p["wo"], (ckv, k_rope)


# -------------------------------------------------------------------------
# MLA (deepseek-v2)
# -------------------------------------------------------------------------
def init_mla(key, cfg) -> tuple[dict, dict]:
    d, h = cfg.d_model, cfg.padded_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    cl, ql = cfg.kv_lora, cfg.q_lora
    ks = jax.random.split(key, 8)
    p: dict[str, Any] = {}
    s: dict[str, Any] = {}
    if ql:
        p["wdq"] = dense_init(ks[0], d, ql, cfg.param_dtype)
        s["wdq"] = ("embed", None)
        p["q_norm"], s["q_norm"] = init_rms(ql, cfg.param_dtype)
        p["wuq"] = dense_init(ks[1], ql, h * (dn + dr), cfg.param_dtype)
        s["wuq"] = (None, "heads")
    else:
        p["wq"] = dense_init(ks[1], d, h * (dn + dr), cfg.param_dtype)
        s["wq"] = ("embed", "heads")
    p["wdkv"] = dense_init(ks[2], d, cl + dr, cfg.param_dtype)
    s["wdkv"] = ("embed", None)
    p["kv_norm"], s["kv_norm"] = init_rms(cl, cfg.param_dtype)
    p["wuk"] = dense_init(ks[3], cl, h * dn, cfg.param_dtype)
    s["wuk"] = ("kv_lora", "heads")
    p["wuv"] = dense_init(ks[4], cl, h * dv, cfg.param_dtype)
    s["wuv"] = ("kv_lora", "heads")
    p["wo"] = dense_init(ks[5], h * dv, d, cfg.param_dtype)
    s["wo"] = ("heads", "embed")
    return p, s


def _mla_q(p, x, cfg, positions):
    b, t, _ = x.shape
    h, dn, dr = cfg.padded_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    if cfg.q_lora:
        q = rms_norm(p["q_norm"], x @ p["wdq"]) @ p["wuq"]
    else:
        q = x @ p["wq"]
    q = q.reshape(b, t, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    cos, sin = rope_tables(positions, dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos[:, :, None, :], sin[:, :, None, :])
    return q_nope, q_rope


def _mla_ckv(p, x, cfg, positions):
    dr, cl = cfg.qk_rope_dim, cfg.kv_lora
    dkv = x @ p["wdkv"]
    ckv = rms_norm(p["kv_norm"], dkv[..., :cl])
    k_rope = dkv[..., cl:]
    cos, sin = rope_tables(positions, dr, cfg.rope_theta)
    k_rope = apply_rope(k_rope, cos, sin)
    return ckv, k_rope


def mla_train(p, x, cfg, positions):
    b, t, _ = x.shape
    h = cfg.padded_heads
    dn, dr, dv, cl = (cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim,
                      cfg.kv_lora)
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    ckv, k_rope = _mla_ckv(p, x, cfg, positions)
    k_nope = (ckv @ p["wuk"]).reshape(b, t, h, dn)
    v = (ckv @ p["wuv"]).reshape(b, t, h, dv)
    scores = (jnp.einsum("bthd,bshd->bhts", q_nope, k_nope) +
              jnp.einsum("bthd,bsd->bhts", q_rope, k_rope)) / float(np.sqrt(dn + dr))
    mask = jnp.tril(jnp.ones((t, t), bool))
    scores = jnp.where(mask[None, None], scores.astype(jnp.float32), -1e30)
    scores = shard_act(scores, SCORES)
    probs = shard_act(jax.nn.softmax(scores, axis=-1), SCORES).astype(x.dtype)
    ctx = jnp.einsum("bhts,bshd->bthd", probs, v).reshape(b, t, h * dv)
    return ctx @ p["wo"], (ckv, k_rope)


def mla_decode(p, x, cache_ckv, cache_kr, lengths, cfg):
    """Absorbed-matmul MLA decode: scores live in the compressed space, so
    the cache is tiny ([S, kv_lora + rope]) and per-step FLOPs scale with
    kv_lora, not heads x head_dim."""
    b = x.shape[0]
    h = cfg.padded_heads
    dn, dr, dv, cl = (cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim,
                      cfg.kv_lora)
    positions = lengths[:, None]
    q_nope, q_rope = _mla_q(p, x, cfg, positions)   # [b, 1, h, .]
    ckv_new, kr_new = _mla_ckv(p, x, cfg, positions)  # [b, 1, cl], [b, 1, dr]
    z = jnp.int32(0)  # x64 mode: literal 0 would promote to int64
    cache_ckv = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
        c, u, (i, z)))(cache_ckv, ckv_new, lengths)
    cache_kr = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
        c, u, (i, z)))(cache_kr, kr_new, lengths)
    wuk = p["wuk"].reshape(cl, h, dn)
    q_lat = jnp.einsum("bhd,chd->bhc", q_nope[:, 0], wuk)   # absorb W_uk
    scores = (jnp.einsum("bhc,bsc->bhs", q_lat, cache_ckv) +
              jnp.einsum("bhd,bsd->bhs", q_rope[:, 0], cache_kr))
    scores = scores / float(np.sqrt(dn + dr))
    s_len = cache_ckv.shape[1]
    valid = jnp.arange(s_len)[None] <= lengths[:, None]
    scores = jnp.where(valid[:, None], scores.astype(jnp.float32), -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx_lat = jnp.einsum("bhs,bsc->bhc", probs, cache_ckv)
    wuv = p["wuv"].reshape(cl, h, dv)
    ctx = jnp.einsum("bhc,chd->bhd", ctx_lat, wuv).reshape(b, 1, h * dv)
    return ctx @ p["wo"], cache_ckv, cache_kr
