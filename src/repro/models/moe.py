"""Mixture-of-Experts FFN (deepseek-v2 style: shared + routed top-k).

Dispatch is **sort-based with fixed capacity** (the TPU-friendly dropless
approximation): token-expert assignments are sorted by expert id, each
expert receives up to C = ceil(T k / E) * capacity_factor rows, overflow
drops (scored in the aux loss).  This avoids the O(T E C) one-hot dispatch
tensor of the classic Mesh-TF einsum formulation, which is infeasible at
160 experts x 32k tokens.

Expert weight tensors are stacked [E, ...] and sharded on the "experts"
(-> model) axis; the dispatch buffer [E, C, D] inherits that sharding, so
XLA lowers the scatter/gather pair into an all-to-all across the expert
axis (verified in the dry-run HLO; see EXPERIMENTS.md SDry-run).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense_init, swiglu
from repro.sharding import shard_act


def init_moe(key, cfg) -> tuple[dict, dict]:
    d, e, f = cfg.d_model, cfg.moe_experts, cfg.moe_d_ff
    fs = cfg.moe_shared * cfg.moe_d_ff
    ks = jax.random.split(key, 7)
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32) /
                   np.sqrt(d)).astype(cfg.param_dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f), jnp.float32) /
                 np.sqrt(d)).astype(cfg.param_dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d), jnp.float32) /
                   np.sqrt(f)).astype(cfg.param_dtype),
        "ws_gate": dense_init(ks[4], d, fs, cfg.param_dtype),
        "ws_up": dense_init(ks[5], d, fs, cfg.param_dtype),
        "ws_down": dense_init(ks[6], fs, d, cfg.param_dtype),
    }
    s = {
        "router": ("embed", None),
        "w_gate": ("experts", "expert_embed", "expert_mlp"),
        "w_up": ("experts", "expert_embed", "expert_mlp"),
        "w_down": ("experts", "expert_mlp", "expert_embed"),
        "ws_gate": ("embed", "mlp"),
        "ws_up": ("embed", "mlp"),
        "ws_down": ("mlp", "embed"),
    }
    return p, s


def moe_ffn(p, x, cfg):
    """x [b, t, d] -> (out [b, t, d], aux_loss scalar).

    Grouped local dispatch: tokens are reshaped to [G, T/G, d] with the
    group axis sharded on "batch" (the data axis).  The argsort, the
    token gather and the dispatch scatter then run *per group* -- batched
    ops over a 1-per-device leading dim stay shard-local under SPMD --
    and the only cross-device movement is the [G, E, C, D] buffer's
    group->expert resharding, i.e. the canonical MoE all-to-all.

    (First formulation used one global sort: SPMD replicated the
    [T*k, d] gathered tokens on every device -- 120 GiB/device on
    deepseek-v2-236b/train_4k.  EXPERIMENTS.md SPerf cell-A it-1.)
    """
    b, t, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_top_k
    n_tok = b * t
    g = min(cfg.moe_groups, n_tok) or 1
    while n_tok % g:
        g //= 2
    tg = n_tok // g                                            # tokens/group
    cap = int(np.ceil(tg * k / e * cfg.moe_capacity_factor))
    tokens = x.reshape(g, tg, d)
    tokens = shard_act(tokens, ("batch", None, None))

    logits = (tokens.astype(jnp.float32) @ p["router"])        # [G, Tg, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                     # [G, Tg, k]
    if cfg.moe_norm_topk:
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # aux load-balance loss (switch-style)
    density = jnp.mean(jax.nn.one_hot(top_e[..., 0], e), axis=(0, 1))
    density_proxy = jnp.mean(probs, axis=(0, 1))
    aux = jnp.sum(density * density_proxy) * e

    # ---- per-group sort-based dispatch ---------------------------------
    flat_e = top_e.reshape(g, tg * k)
    flat_p = top_p.reshape(g, tg * k)
    flat_tok = jnp.broadcast_to(
        jnp.repeat(jnp.arange(tg, dtype=jnp.int32), k)[None], (g, tg * k))
    order = jnp.argsort(flat_e, axis=1, stable=True)
    se = jnp.take_along_axis(flat_e, order, axis=1)
    st = jnp.take_along_axis(flat_tok, order, axis=1)
    sp = jnp.take_along_axis(flat_p, order, axis=1)
    # rank within expert block (per group)
    first_of_e = jax.vmap(
        lambda row: jnp.searchsorted(row, row, side="left"))(se)
    pos = (jnp.arange(tg * k, dtype=jnp.int32)[None] - first_of_e)
    keep = pos < cap
    slot_e = jnp.where(keep, se, e)                            # dump expert
    slot_c = jnp.where(keep, pos, 0)

    # vmapped per-group scatter/gather: batched ops over the sharded
    # group dim stay shard-local under SPMD (explicit group indices in a
    # flat scatter defeat the partitioner -- SPerf cell-A it-2)
    def disp(tok_g, se_g, sc_g, st_g):
        picked = jnp.take(tok_g, st_g, axis=0)                 # [Tgk, D]
        return jnp.zeros((e + 1, cap, d), x.dtype).at[
            se_g, sc_g].set(picked)

    buf = jax.vmap(disp)(tokens, slot_e, slot_c, st)           # [G,E+1,C,D]
    # group axis: data-sharded; expert axis: model-sharded -> all-to-all
    h = shard_act(buf[:, :e], ("batch", "experts", None, None))
    act = swiglu(jnp.einsum("gecd,edf->gecf", h, p["w_gate"]),
                 jnp.einsum("gecd,edf->gecf", h, p["w_up"]))
    out_e = jnp.einsum("gecf,efd->gecd", act, p["w_down"])     # [G,E,C,D]
    out_e = shard_act(out_e, ("batch", "experts", None, None))

    def undisp(out_g, se_g, sc_g, st_g, w_g):
        gathered = out_g[se_g, sc_g] * w_g[:, None].astype(x.dtype)
        return jnp.zeros((tg, d), x.dtype).at[st_g].add(gathered)

    out_pad = jnp.concatenate(
        [out_e, jnp.zeros((g, 1, cap, d), x.dtype)], axis=1)
    routed = jax.vmap(undisp)(out_pad, slot_e, slot_c, st, sp * keep)

    shared = swiglu(tokens @ p["ws_gate"], tokens @ p["ws_up"]) @ p["ws_down"]
    return (routed + shared).reshape(b, t, d), aux


def init_dense_ffn(key, d: int, f: int, dtype) -> tuple[dict, dict]:
    ks = jax.random.split(key, 3)
    p = {"w_gate": dense_init(ks[0], d, f, dtype),
         "w_up": dense_init(ks[1], d, f, dtype),
         "w_down": dense_init(ks[2], f, d, dtype)}
    s = {"w_gate": ("embed", "mlp"), "w_up": ("embed", "mlp"),
         "w_down": ("mlp", "embed")}
    return p, s


def dense_ffn(p, x):
    return swiglu(x @ p["w_gate"], x @ p["w_up"]) @ p["w_down"]
